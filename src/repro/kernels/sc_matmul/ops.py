"""Public ops: SC integer matmul + the drop-in quantized linear layer.

`sc_quantized_linear` is the `ExecutionPolicy(quant="sc_w16a16")` path behind
every architecture's MLP/projection layers (DESIGN §Arch-applicability):
float in, float out, SC-CIM integer GEMM inside.  Backend selection goes
through the kernel registry like every other kernel — `nn.linear` pipes the
policy's backend/interpret flags straight here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import quantize_symmetric
from repro.kernels import registry
from repro.kernels.sc_matmul.kernel import sc_matmul_pallas
from repro.kernels.sc_matmul.ref import sc_matmul_ref

registry.register(
    "sc_matmul",
    xla=lambda x, w, *, n_planes: sc_matmul_ref(x, w, n_planes=n_planes),
    pallas=lambda x, w, *, n_planes, interpret: sc_matmul_pallas(
        x, w, n_planes_x=n_planes, n_planes_w=n_planes, interpret=interpret
    ),
)


def sc_matmul_op(
    x_q: jax.Array,
    w_q: jax.Array,
    *,
    bits: int = 16,
    backend: str = "auto",
    interpret: bool | None = None,
) -> jax.Array:
    """Exact integer matmul via SC planes.  (M,K) x (K,N) int32 -> (M,N) f32."""
    n_planes = bits // 4
    _, impl = registry.dispatch("sc_matmul", backend, interpret)
    return impl(x_q, w_q, n_planes=n_planes)


def sc_quantized_linear(
    x: jax.Array,
    w: jax.Array,
    *,
    bits: int = 16,
    backend: str = "auto",
    interpret: bool | None = None,
    amax_axis: str | None = None,
) -> jax.Array:
    """W16A16 linear: float (..., K) x (K, N) -> float32 (..., N).

    amax_axis: mapped mesh axis to globalize the ACTIVATION scale over
    (shard_map batch sharding) — the weight is replicated, so its local
    amax already equals the global one.
    """
    lead = x.shape[:-1]
    xq = quantize_symmetric(x.reshape(-1, x.shape[-1]), bits, axis_name=amax_axis)
    wq = quantize_symmetric(w, bits)
    y = sc_matmul_op(xq.q, wq.q, bits=bits, backend=backend, interpret=interpret)
    y = y * (xq.scale * wq.scale)
    return y.reshape(lead + (w.shape[-1],)).astype(jnp.float32)
