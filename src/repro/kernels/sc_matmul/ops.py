"""Public ops: SC integer matmul + the drop-in quantized linear layer.

`sc_quantized_linear` is the `quant_mode="sc_w16a16"` path exposed to every
architecture's MLP/projection layers (DESIGN §Arch-applicability): float in,
float out, SC-CIM integer GEMM inside.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import quantize_symmetric
from repro.kernels.sc_matmul.kernel import sc_matmul_pallas
from repro.kernels.sc_matmul.ref import sc_matmul_ref


def sc_matmul_op(
    x_q: jax.Array,
    w_q: jax.Array,
    *,
    bits: int = 16,
    backend: str = "auto",
    interpret: bool | None = None,
) -> jax.Array:
    """Exact integer matmul via SC planes.  (M,K) x (K,N) int32 -> (M,N) f32."""
    n_planes = bits // 4
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend == "xla":
        return sc_matmul_ref(x_q, w_q, n_planes=n_planes)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return sc_matmul_pallas(
        x_q, w_q, n_planes_x=n_planes, n_planes_w=n_planes, interpret=interpret
    )


def sc_quantized_linear(
    x: jax.Array,
    w: jax.Array,
    *,
    bits: int = 16,
    backend: str = "auto",
    interpret: bool | None = None,
) -> jax.Array:
    """W16A16 linear: float (..., K) x (K, N) -> float32 (..., N)."""
    lead = x.shape[:-1]
    xq = quantize_symmetric(x.reshape(-1, x.shape[-1]), bits)
    wq = quantize_symmetric(w, bits)
    y = sc_matmul_op(xq.q, wq.q, bits=bits, backend=backend, interpret=interpret)
    y = y * (xq.scale * wq.scale)
    return y.reshape(lead + (w.shape[-1],)).astype(jnp.float32)
