"""Pure-jnp oracle for the SC matmul kernel (reuses core.quant — itself
property-tested against int64 numpy)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import sc_matmul


def sc_matmul_ref(
    x_q: jax.Array, w_q: jax.Array, *, n_planes: int = 4
) -> jax.Array:
    """f32-combine reference — identical arithmetic schedule to the kernel."""
    return sc_matmul(x_q, w_q, n_planes=n_planes, combine="f32")


def int_matmul_ref(x_q: jax.Array, w_q: jax.Array) -> jax.Array:
    """Plain integer matmul in f64-exact numpy semantics (via f32 when safe)."""
    return jnp.asarray(x_q, jnp.float32) @ jnp.asarray(w_q, jnp.float32)
