"""Unified kernel-backend registry — one place for dispatch, fallback, padding.

Every public op under kernels/ used to carry its own copy of the same three
concerns:

  1. backend resolution   — "auto" means pallas on TPU, the XLA reference
                            everywhere else;
  2. interpret fallback   — pallas kernels run in interpret mode on non-TPU
                            hosts so the whole suite is testable on CPU;
  3. lane/sublane padding — TPU lane width is 128; inputs are padded with
                            copies of the first slice (optionally pushed far
                            out of range) so padded lanes can never win a
                            distance comparison.

This module centralises all three.  Kernels self-register an (xla, pallas)
implementation pair under a name; ops call `dispatch(name, backend=...,
interpret=...)` and get back the resolved callable.  The registry is also the
natural seam for future backends (e.g. a CUDA path) and for forcing a global
backend in tests via `force_backend`.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading
from typing import Callable

import jax
import jax.numpy as jnp

LANE = 128  # TPU lane width: last-dim block multiples
SUBLANE = 8  # f32 sublane multiple (second-to-last dim)

#: padding offset that pushes filler points out of every distance range while
#: staying finite (inf would NaN the |a-b| math inside the kernels).
FAR_OFFSET = 1e15

_BACKENDS = ("pallas", "xla")


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One registered kernel: a Pallas implementation + its XLA oracle."""

    name: str
    xla: Callable
    pallas: Callable


_REGISTRY: dict[str, KernelSpec] = {}
_LOCAL = threading.local()


def register(name: str, *, xla: Callable, pallas: Callable) -> KernelSpec:
    """Register (or replace) a kernel implementation pair under `name`."""
    spec = KernelSpec(name=name, xla=xla, pallas=pallas)
    _REGISTRY[name] = spec
    return spec


def get(name: str) -> KernelSpec:
    if name not in _REGISTRY:
        raise KeyError(f"unknown kernel {name!r}; registered: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names() -> list[str]:
    return sorted(_REGISTRY)


@contextlib.contextmanager
def force_backend(backend: str | None):
    """Override every "auto" resolution inside the context (None = no-op).

    Resolution happens at TRACE time: a jitted function (or cached
    PreprocessEngine) that already traced with some backend will replay its
    cache and never consult the override.  Use this around first-trace code
    paths (fresh shapes / fresh engines); to pin a backend durably, pass it
    explicitly (EngineConfig(backend=...) participates in engine identity).
    """
    prev = getattr(_LOCAL, "forced", None)
    _LOCAL.forced = backend
    try:
        yield
    finally:
        _LOCAL.forced = prev


def resolve_backend(
    backend: str = "auto", interpret: bool | None = None
) -> tuple[str, bool]:
    """Resolve ("auto" | "pallas" | "xla", interpret?) -> (backend, interpret).

    "auto" picks pallas on TPU and the XLA reference elsewhere; interpret
    defaults to True off-TPU so pallas kernels remain runnable on CPU.
    """
    forced = getattr(_LOCAL, "forced", None)
    if backend == "auto" and forced is not None:
        backend = forced
    on_tpu = jax.default_backend() == "tpu"
    if backend == "auto":
        backend = "pallas" if on_tpu else "xla"
    if backend not in _BACKENDS:
        raise ValueError(f"backend must be one of {('auto',) + _BACKENDS}, got {backend!r}")
    if interpret is None:
        interpret = not on_tpu
    return backend, interpret


def dispatch(
    name: str, backend: str = "auto", interpret: bool | None = None
) -> tuple[str, Callable]:
    """Resolve the backend and return (backend, impl).

    The pallas impl is returned partially applied with the resolved interpret
    flag; the xla impl is returned as-is (it has no interpret concept).
    """
    backend, interpret = resolve_backend(backend, interpret)
    spec = get(name)
    if backend == "xla":
        return backend, spec.xla
    return backend, functools.partial(spec.pallas, interpret=interpret)


def pad_to_multiple(
    x: jax.Array, axis: int, multiple: int = LANE, *, offset: float = 0.0
) -> tuple[jax.Array, int]:
    """Pad `axis` of x up to a multiple by repeating the first slice.

    offset=0.0 replicates the first slice exactly (FPS-style padding: the
    duplicate's dmin collapses to 0 after step one, so it can never be
    sampled before any real point).  offset=FAR_OFFSET pushes the filler out
    of every query range (query-style padding).  Returns (padded, pad_count).
    """
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, 0
    sl = [slice(None)] * x.ndim
    sl[axis] = slice(0, 1)
    filler = x[tuple(sl)] + jnp.asarray(offset, x.dtype)
    shape = list(x.shape)
    shape[axis] = pad
    return jnp.concatenate([x, jnp.broadcast_to(filler, shape)], axis=axis), pad
