"""Pallas kernel: fused lattice query (C1) — L1 distance + box mask + first-k.

The full (M, P) distance matrix never reaches HBM: per centroid block, the
kernel computes L1 distances into VMEM, thresholds at L = 1.6R, and selects
the FIRST `nsample` in-range indices (PointNet++ semantics) via a cumsum
slot-match — all in one pass.  HBM output is just (M, nsample) indices +
mask, exactly the paper's 'distances are consumed in-situ by the sorter'.

first-k as dense ops (Mosaic-friendly, no scatter):
    hits   = d <= L                      (bc, P)
    ranks  = cumsum(hits) along P        (bc, P)  1-based at hit positions
    slot s taken by the column j with hits[j] and ranks[j] == s+1
    idx[s] = min over j of (hits & ranks==s+1 ? j : P)   -> (bc, nsample)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lattice_kernel(c_ref, p_ref, idx_ref, mask_ref, *, nsample: int, l_range: float):
    """c_ref (bc, 3), p_ref (3, P) -> idx (bc, nsample) int32, mask bool."""
    c = c_ref[...]
    p = p_ref[...]
    d = jnp.sum(jnp.abs(c[:, :, None] - p[None, :, :]), axis=1)  # (bc, P) L1
    bc, pp = d.shape
    hits = d <= l_range
    ranks = jnp.cumsum(hits.astype(jnp.int32), axis=1)  # (bc, P)
    lane = jax.lax.broadcasted_iota(jnp.int32, (bc, pp), 1)
    for s in range(nsample):
        sel = hits & (ranks == (s + 1))
        j = jnp.min(jnp.where(sel, lane, pp), axis=1)  # (bc,)
        found = j < pp
        idx_ref[:, s] = jnp.where(found, j, 0).astype(jnp.int32)
        mask_ref[:, s] = found
    # pad empty slots with the first hit (PointNet++ convention)
    first = idx_ref[:, 0]
    for s in range(1, nsample):
        m = mask_ref[:, s]
        idx_ref[:, s] = jnp.where(m, idx_ref[:, s], first)


@functools.partial(
    jax.jit, static_argnames=("nsample", "l_range", "interpret")
)
def lattice_tiles_pallas(
    centroids: jax.Array,
    points: jax.Array,
    *,
    nsample: int,
    l_range: float,
    interpret: bool = False,
):
    """Per-tile lattice query in ONE grid: each program queries one tile's
    centroids against that tile's own points (the MSP-local dataflow).

    centroids (T, K, 3), points (T, 3, P) -> idx (T, K, nsample) int32,
    mask (T, K, nsample) bool.  The tile axis is the pallas grid — the
    PreprocessEngine folds (batch x MSP-tiles) into T, so B clouds run as a
    single launch.  `None` block dims squeeze the tile axis, so the body is
    the exact same `_lattice_kernel` as the flat variant below.
    """
    t, kk, three = centroids.shape
    assert three == 3 and points.shape[0] == t and points.shape[1] == 3
    p = points.shape[2]
    if p % 128 != 0:
        raise ValueError(f"P={p} must be a multiple of 128")

    kernel = functools.partial(_lattice_kernel, nsample=nsample, l_range=l_range)
    return pl.pallas_call(
        kernel,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((None, kk, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, 3, p), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, kk, nsample), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, kk, nsample), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, kk, nsample), jnp.int32),
            jax.ShapeDtypeStruct((t, kk, nsample), jnp.bool_),
        ],
        interpret=interpret,
        name="pc2im_lattice_tiles",
    )(centroids, points)


@functools.partial(
    jax.jit, static_argnames=("nsample", "l_range", "bc", "interpret")
)
def lattice_pallas(
    centroids: jax.Array,
    points: jax.Array,
    *,
    nsample: int,
    l_range: float,
    bc: int = 128,
    interpret: bool = False,
):
    """centroids (M, 3), points (3, P) -> (idx (M,nsample), mask (M,nsample))."""
    m, three = centroids.shape
    assert three == 3 and points.shape[0] == 3
    p = points.shape[1]
    if p % 128 != 0:
        raise ValueError(f"P={p} must be a multiple of 128")
    bc = min(bc, m)
    if m % bc != 0:
        raise ValueError(f"M={m} not divisible by block {bc}")

    kernel = functools.partial(_lattice_kernel, nsample=nsample, l_range=l_range)
    return pl.pallas_call(
        kernel,
        grid=(m // bc,),
        in_specs=[
            pl.BlockSpec((bc, 3), lambda i: (i, 0)),
            pl.BlockSpec((3, p), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bc, nsample), lambda i: (i, 0)),
            pl.BlockSpec((bc, nsample), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, nsample), jnp.int32),
            jax.ShapeDtypeStruct((m, nsample), jnp.bool_),
        ],
        interpret=interpret,
        name="pc2im_lattice_query",
    )(centroids, points)
