"""Pure-jnp oracle for the fused lattice query — core.query.lattice_query
(itself tested against ball-query coverage properties)."""

from __future__ import annotations

import jax

from repro.core.query import lattice_query


def lattice_ref(centroids: jax.Array, points_t: jax.Array, *, nsample: int, l_range: float):
    res = lattice_query(
        points_t.T, centroids, radius=l_range, nsample=nsample, range_factor=1.0
    )
    return res.idx, res.mask
