"""Public op: fused lattice query with backend selection."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.query import LATTICE_RANGE_FACTOR, NeighborSet
from repro.kernels.lattice.kernel import lattice_pallas
from repro.kernels.lattice.ref import lattice_ref


def lattice_query_fused(
    points: jax.Array,
    centroids: jax.Array,
    radius: float,
    nsample: int,
    *,
    range_factor: float = LATTICE_RANGE_FACTOR,
    backend: str = "auto",
    interpret: bool | None = None,
) -> NeighborSet:
    """Drop-in fused version of core.query.lattice_query (same signature order)."""
    l_range = float(radius * range_factor)
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    pts_t = points.T
    if backend == "xla":
        idx, mask = lattice_ref(centroids, pts_t, nsample=nsample, l_range=l_range)
        return NeighborSet(idx=idx, mask=mask)

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, p = centroids.shape[0], points.shape[0]
    pad_p = (-p) % 128
    if pad_p:
        filler = pts_t[:, :1] + 1e15  # finite, out of any lattice range
        pts_t = jnp.concatenate([pts_t, jnp.broadcast_to(filler, (3, pad_p))], axis=1)
    bc = 128 if m % 128 == 0 else (m if m <= 128 else None)
    pad_m = 0
    if bc is None:
        bc = 128
        pad_m = (-m) % bc
        centroids = jnp.concatenate(
            [centroids, jnp.broadcast_to(centroids[:1] + 1e15, (pad_m, 3))], axis=0
        )
    idx, mask = lattice_pallas(
        centroids.astype(jnp.float32), pts_t.astype(jnp.float32),
        nsample=nsample, l_range=l_range, bc=bc, interpret=interpret,
    )
    return NeighborSet(idx=idx[:m], mask=mask[:m])
