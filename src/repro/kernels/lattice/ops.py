"""Public ops: fused lattice query (flat + per-tile) via the kernel registry."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.query import LATTICE_RANGE_FACTOR, NeighborSet, lattice_query
from repro.kernels import registry
from repro.kernels.lattice.kernel import lattice_pallas, lattice_tiles_pallas
from repro.kernels.lattice.ref import lattice_ref

registry.register("lattice_query", xla=lattice_ref, pallas=lattice_pallas)
registry.register(
    "lattice_query_tiles",
    xla=lambda coords, cxyz, *, nsample, l_range: jax.vmap(
        lambda c, cx: lattice_query(c, cx, l_range, nsample, range_factor=1.0)
    )(coords, cxyz),
    pallas=lattice_tiles_pallas,
)


def lattice_query_fused(
    points: jax.Array,
    centroids: jax.Array,
    radius: float,
    nsample: int,
    *,
    range_factor: float = LATTICE_RANGE_FACTOR,
    backend: str = "auto",
    interpret: bool | None = None,
) -> NeighborSet:
    """Drop-in fused version of core.query.lattice_query (same signature order)."""
    l_range = float(radius * range_factor)
    resolved, impl = registry.dispatch("lattice_query", backend, interpret)
    pts_t = points.T
    if resolved == "xla":
        idx, mask = impl(centroids, pts_t, nsample=nsample, l_range=l_range)
        return NeighborSet(idx=idx, mask=mask)

    m = centroids.shape[0]
    pts_t, _ = registry.pad_to_multiple(
        pts_t, axis=1, multiple=registry.LANE, offset=registry.FAR_OFFSET
    )
    bc = 128 if m % 128 == 0 else (m if m <= 128 else None)
    if bc is None:
        bc = 128
        centroids, _ = registry.pad_to_multiple(
            centroids, axis=0, multiple=bc, offset=registry.FAR_OFFSET
        )
    idx, mask = impl(
        centroids.astype(jnp.float32), pts_t.astype(jnp.float32),
        nsample=nsample, l_range=l_range, bc=bc,
    )
    return NeighborSet(idx=idx[:m], mask=mask[:m])


def lattice_query_tiles(
    coords: jax.Array,
    centroids: jax.Array,
    radius: float,
    nsample: int,
    *,
    range_factor: float = LATTICE_RANGE_FACTOR,
    backend: str = "auto",
    interpret: bool | None = None,
) -> NeighborSet:
    """Per-tile lattice query: each tile's centroids against its own points.

    coords (T, P, 3), centroids (T, K, 3) -> NeighborSet with idx/mask
    (T, K, nsample), indices LOCAL to each tile.  One pallas grid covers all
    T tiles — the PreprocessEngine folds (B, tiles) into T for one launch.
    """
    t, p, three = coords.shape
    assert three == 3 and centroids.shape[0] == t
    l_range = float(radius * range_factor)
    resolved, impl = registry.dispatch("lattice_query_tiles", backend, interpret)
    if resolved == "xla":
        idx, mask = impl(coords, centroids, nsample=nsample, l_range=l_range)
        return NeighborSet(idx=idx, mask=mask)

    pts_t = coords.transpose(0, 2, 1)  # (T, 3, P)
    pts_t, _ = registry.pad_to_multiple(
        pts_t, axis=2, multiple=registry.LANE, offset=registry.FAR_OFFSET
    )
    idx, mask = impl(
        centroids.astype(jnp.float32), pts_t.astype(jnp.float32),
        nsample=nsample, l_range=l_range,
    )
    return NeighborSet(idx=idx, mask=mask)
