from repro.kernels.lattice.ops import lattice_query_fused  # noqa: F401
