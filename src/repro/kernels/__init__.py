"""Pallas TPU kernels for PC2IM's compute hot-spots.

fps/        in-VMEM farthest-point-sampling loop — the APD-CIM + Ping-Pong-MAX
            CAM analogue: the point tile and the temporary-distance vector
            stay in VMEM for the entire K-step loop (C1+C3).
sc_matmul/  split-concatenate W16A16 integer matmul via 4-bit planes on the
            int8 MXU path (C4).
knn3/       fused 3-nearest-neighbour (3x min-extract) for FP layers.
lattice/    fused L1-distance + box-mask + first-k neighbour select (C1).

Each kernel: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd public
wrapper with interpret switch), ref.py (pure-jnp oracle).  All validated in
interpret mode on CPU; BlockSpecs are sized for TPU v5e VMEM (16 MB less
double-buffering headroom) with lane-dim multiples of 128.
"""
