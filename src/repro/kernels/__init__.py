"""Pallas TPU kernels for PC2IM's compute hot-spots.

fps/        in-VMEM farthest-point-sampling loop — the APD-CIM + Ping-Pong-MAX
            CAM analogue: the point tile and the temporary-distance vector
            stay in VMEM for the entire K-step loop (C1+C3).
sc_matmul/  split-concatenate W16A16 integer matmul via 4-bit planes on the
            int8 MXU path (C4).
knn3/       fused 3-nearest-neighbour (3x min-extract) for FP layers.
lattice/    fused L1-distance + box-mask + first-k neighbour select (C1).

Each kernel: kernel.py (pl.pallas_call + BlockSpec), ops.py (public wrapper),
ref.py (pure-jnp oracle).  Backend selection, interpret-mode fallback and
lane padding all go through registry.py — ops register an (xla, pallas) pair
and call registry.dispatch.  All kernels validate in interpret mode on CPU;
BlockSpecs are sized for TPU v5e VMEM (16 MB less double-buffering headroom)
with lane-dim multiples of 128.
"""
