"""Procedural 3D shape dataset — seeded, deterministic, fully-on-device.

8 classes with distinct geometry: sphere, cube(surface), cylinder, cone,
torus, plane, helix, cross.  Each sample is randomly rotated, scaled and
jittered, so classification requires real shape features.  Per-point
segmentation labels = octant of the point in the shape's CANONICAL frame
(the net must undo the rotation from geometry alone).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

N_CLASSES = 8
N_SEG_CLASSES = 8  # canonical octants


def _unit(x, axis=-1, eps=1e-9):
    return x / (jnp.linalg.norm(x, axis=axis, keepdims=True) + eps)


def _make_shape(cls_id: int, key, n: int) -> jax.Array:
    """Canonical-frame points for one shape class.  (N, 3) in [-1, 1]^3-ish."""
    k1, k2, k3 = jax.random.split(key, 3)
    u = jax.random.uniform(k1, (n, 3), minval=-1.0, maxval=1.0)
    t = jax.random.uniform(k2, (n,), minval=0.0, maxval=1.0)

    sphere = _unit(jax.random.normal(k3, (n, 3)))
    # cube surface: project onto the largest |coord| face
    m = jnp.argmax(jnp.abs(u), axis=1)
    cube = u.at[jnp.arange(n), m].set(jnp.sign(u[jnp.arange(n), m]))
    theta = 2 * jnp.pi * t
    cylinder = jnp.stack([jnp.cos(theta), jnp.sin(theta), u[:, 2]], axis=1)
    r_cone = 1.0 - t
    cone = jnp.stack([r_cone * jnp.cos(theta), r_cone * jnp.sin(theta), 2 * t - 1], axis=1)
    phi = 2 * jnp.pi * u[:, 0]
    torus = jnp.stack(
        [
            (0.7 + 0.3 * jnp.cos(phi)) * jnp.cos(theta),
            (0.7 + 0.3 * jnp.cos(phi)) * jnp.sin(theta),
            0.3 * jnp.sin(phi),
        ],
        axis=1,
    )
    plane = jnp.stack([u[:, 0], u[:, 1], 0.05 * u[:, 2]], axis=1)
    hz = 2 * t - 1
    helix = jnp.stack([jnp.cos(3 * jnp.pi * hz), jnp.sin(3 * jnp.pi * hz), hz], axis=1)
    helix = helix + 0.05 * u  # thickness
    # cross: two orthogonal bars
    bar = jnp.stack([u[:, 0], 0.15 * u[:, 1], 0.15 * u[:, 2]], axis=1)
    swap = (u[:, 2] > 0)[:, None]
    cross = jnp.where(swap, bar[:, [1, 0, 2]], bar)

    shapes = jnp.stack([sphere, cube, cylinder, cone, torus, plane, helix, cross])
    return shapes[cls_id]


def _random_rotation(key) -> jax.Array:
    """Uniform random rotation matrix (QR of a Gaussian, det fixed to +1)."""
    a = jax.random.normal(key, (3, 3))
    q, r = jnp.linalg.qr(a)
    q = q * jnp.sign(jnp.diagonal(r))[None, :]
    det = jnp.linalg.det(q)
    return q.at[:, 0].multiply(jnp.sign(det))


@functools.partial(jax.jit, static_argnames=("n_points", "batch"))
def sample_batch(key, batch: int, n_points: int = 1024):
    """Returns (points (B, N, 3) f32, cls_labels (B,), seg_labels (B, N))."""
    keys = jax.random.split(key, batch)

    def one(k):
        kc, ks, kr, kj, kscale = jax.random.split(k, 5)
        cls_id = jax.random.randint(kc, (), 0, N_CLASSES)
        branches = [
            functools.partial(lambda c, k: _make_shape(c, k, n_points), c)
            for c in range(N_CLASSES)
        ]
        canon = jax.lax.switch(cls_id, branches, ks)
        seg = (
            (canon[:, 0] > 0).astype(jnp.int32) * 4
            + (canon[:, 1] > 0).astype(jnp.int32) * 2
            + (canon[:, 2] > 0).astype(jnp.int32)
        )
        rot = _random_rotation(kr)
        scale = jax.random.uniform(kscale, (), minval=0.7, maxval=1.3)
        pts = (canon * scale) @ rot.T
        pts = pts + 0.02 * jax.random.normal(kj, pts.shape)
        return pts.astype(jnp.float32), cls_id, seg

    return jax.vmap(one)(keys)


def data_stream(seed: int, batch: int, n_points: int = 1024, *, shard_id: int = 0, n_shards: int = 1):
    """Infinite deterministic host-shardable stream (fault-tolerant restart:
    step -> key is pure, so resuming at step S reproduces the exact batch)."""
    step = 0
    while True:
        key = jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed), step), shard_id * 7919)
        yield sample_batch(key, batch, n_points)
        step += n_shards
