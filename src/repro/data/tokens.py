"""Synthetic LM token pipeline — seeded, host-sharded, restart-exact.

The stream is a pure function of (seed, step, shard) so fault-tolerant
restart reproduces the exact batch sequence with zero coordination (the
property a 1000-node data loader needs; a real corpus reader would put its
file/offset cursor in the checkpoint `extra` instead).

Sequences are Zipf-ish Markov chains, not uniform noise, so small-scale
training sanity checks (loss decreasing below unigram entropy) are
meaningful.  A background prefetch thread keeps `depth` batches ready.
"""

from __future__ import annotations

import queue
import threading

import jax
import jax.numpy as jnp


def synth_batch(key, batch: int, seq: int, vocab: int):
    """Markov-ish synthetic tokens: x_{t+1} = (a * x_t + b + noise) % vocab."""
    k1, k2, k3 = jax.random.split(key, 3)
    a = jax.random.randint(k1, (batch, 1), 1, 8)
    x0 = jax.random.randint(k2, (batch, 1), 0, vocab)
    noise = jax.random.randint(k3, (batch, seq), 0, 3)

    def step(x, n):
        nxt = (a[:, 0] * x + 7 + n) % vocab
        return nxt, nxt

    _, toks = jax.lax.scan(step, x0[:, 0], noise.T)
    tokens = toks.T  # (batch, seq)
    labels = jnp.roll(tokens, -1, axis=1)
    return {"tokens": tokens, "labels": labels}


def token_stream(
    seed: int,
    batch: int,
    seq: int,
    vocab: int,
    *,
    start_step: int = 0,
    shard_id: int = 0,
    n_shards: int = 1,
):
    step = start_step
    while True:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(seed), step), shard_id * 7919 + 13
        )
        yield step, synth_batch(key, batch, seq, vocab)
        step += 1


class Prefetcher:
    """Background-thread prefetch with bounded depth (double buffering)."""

    def __init__(self, iterator, depth: int = 2):
        self._it = iterator
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item
