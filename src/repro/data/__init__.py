"""Deterministic, seeded synthetic data pipelines.

pointclouds.py  procedural 3D shapes (cls + per-point seg labels) — stands in
                for ModelNet/S3DIS/SemanticKITTI (unavailable offline).
tokens.py       synthetic LM token streams, host-sharded, prefetched.
"""
